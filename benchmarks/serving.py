"""Serving-gateway scorecard as benchmark rows (docs/serving.md).

Three blocks:

* ``serving/gateway_*`` — the real-model continuous-batching gateway on
  the smoke config: decode throughput plus per-token p50/p95/p99 wall
  latency from `ServeReport`.
* ``serving/serve_wave`` — the chaos serving scenario's armed-vs-stock
  delta: in-flight drops saved, warned drops (must be 0 armed), p99
  inflation over the fault-free baseline, recovery cycles, engine-parity
  error.
* ``serving/plan`` — the SLO-aware fleet planner's best cell for a small
  workload ($/1k completed requests).
"""
from __future__ import annotations

from repro.api.session import Session
from repro.chaos import get_scenario, run_scenario

SAMPLES = 8
SEED = 0


def run():
    session = Session.from_arch("qwen3-1.7b", smoke=True)
    out = []

    rep = session.serve(tokens=16, batch=4, prompt_len=8)
    out.append({"name": "serving/gateway_tokens_per_s",
                "value": round(rep.tokens_per_second, 1),
                "derived": f"decode p50={rep.decode_ms_p50:.2f}ms "
                           f"p95={rep.decode_ms_p95:.2f}ms "
                           f"p99={rep.decode_ms_p99:.2f}ms "
                           f"(batch={rep.batch})"})

    card = run_scenario(get_scenario("serve_wave"), session=session,
                        samples=SAMPLES, seed=SEED, smoke=True, live=False)
    srv = card["serving"]
    imp = srv["impact"]
    out.append({"name": "serving/serve_wave",
                "value": imp["drop_delta"],
                "derived": f"armed_warned_drops={imp['armed_dropped_warned']} "
                           f"p99_inflation={imp['p99_inflation']:.2f}x "
                           f"recovery_cycles={imp['recovery_cycles_total']} "
                           f"parity_err="
                           f"{srv['parity']['time_max_rel_err']:.1e} "
                           f"smoke="
                           f"{'pass' if card['smoke']['passed'] else 'FAIL'}"
                           " (in-flight drops saved vs stock)"})

    from repro.serving import ServingWorkload
    best, plans = session.plan_serving(
        replica_counts=(2, 4), providers=("gcp", "aws"),
        workload=ServingWorkload(n_requests=120, arrival_rate_per_s=2.0,
                                 max_tokens=16),
        samples=4, seed=SEED)
    out.append({"name": "serving/plan",
                "value": round(best.cost_per_1k, 4),
                "derived": f"best={best.provider}/{best.region} "
                           f"x{best.replicas} "
                           f"slo={'ok' if best.meets_slo else 'miss'} "
                           f"p99={best.latency_p99_s:.3f}s of "
                           f"{len(plans)} cells ($/1k requests)"})
    return out
