"""Beyond-paper generality: the §III modeling approach applied to OUR LM zoo
on THIS host — real wall-clock step times of the 10 reduced architectures,
C_m from the analytic FLOPs-per-token, fitted with the same OLS + SVR-RBF
pipeline. Shows the paper's data-driven methodology transfers from
CNNs-on-GPUs to transformers/SSMs-on-a-new-backend unchanged.
"""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.configs import ARCH_IDS, TRAIN_4K, get_config
from repro.core.perf_model.regression import LinearModel, kfold_mae, mape
from repro.core.perf_model.svr import grid_search_svr
from repro.models import api

B, S = 2, 32
STEPS = 3


def measure(seed: int = 0):
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        params, _ = api.init(cfg, jax.random.PRNGKey(seed))
        batch = api.make_batch(cfg, TRAIN_4K, batch_override=B,
                               seq_override=S)
        fn = jax.jit(lambda p, b: api.loss_fn(p, cfg, b))
        fn(params, batch).block_until_ready()  # compile
        ts = []
        for _ in range(STEPS):
            t0 = time.monotonic()
            fn(params, batch).block_until_ready()
            ts.append(time.monotonic() - t0)
        c_m = cfg.flops_per_token(S) * B * S / 1e9  # GFLOPs per fwd batch
        rows.append({"arch": arch, "c_m": c_m,
                     "step_time": float(np.median(ts))})
    return rows


def run():
    rows = measure()
    out = []
    for r in rows:
        out.append({"name": f"lm_speed/{r['arch']}",
                    "value": round(r["step_time"] * 1000, 1),
                    "derived": f"C_m={r['c_m']:.2f} GF/fwd (ms per fwd)"})
    c = np.array([r["c_m"] for r in rows])
    t = np.array([r["step_time"] for r in rows])
    corr = float(np.corrcoef(c, t)[0, 1])
    cn = (c - c.min()) / max(c.max() - c.min(), 1e-9)
    km_lin, _ = kfold_mae(lambda X, y: LinearModel().fit(X, y),
                          cn[:, None], t, k=5)
    svr, info = grid_search_svr(cn[:, None], t, "rbf", k=5)
    out.append({"name": "lm_speed/corr_step_time_vs_flops",
                "value": round(corr, 3),
                "derived": ("positive but weaker than the paper's GPU setting"
                            " — smoke-scale steps (1-9 ms) are dispatch-"
                            "overhead-dominated (esp. ssm/hybrid recurrence),"
                            " as the paper's warmup discussion predicts")})
    out.append({"name": "lm_speed/kfold_mae_ols_vs_svr",
                "value": round(km_lin, 4),
                "derived": f"svr_rbf={info['kfold_mae']:.4f} "
                           f"(s; same pipeline as Table II)"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
