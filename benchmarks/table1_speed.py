"""Table I — training speed (steps/s) per (GPU x model), simplest cluster.

Validates the calibrated per-GPU step-time generator against the paper's
published means (the generator is the fleet stand-in; docs/DESIGN.md §2).
"""
from __future__ import annotations

from repro.core.perf_model.speed_model import (TABLE1_MODELS, TABLE1_SPEED,
                                               calibrate_generators)


def run():
    gens = calibrate_generators()
    rows = []
    for gpu, speeds in TABLE1_SPEED.items():
        for model, paper_speed in speeds.items():
            pred = 1.0 / gens[gpu].step_time(TABLE1_MODELS[model])
            err = abs(pred - paper_speed) / paper_speed * 100
            rows.append({"name": f"table1/{gpu}/{model}",
                         "value": round(pred, 3),
                         "derived": f"paper={paper_speed} err%={err:.2f}"})
    errs = [float(r["derived"].split("err%=")[1]) for r in rows]
    rows.append({"name": "table1/MAPE_vs_paper",
                 "value": round(sum(errs) / len(errs), 3), "derived": "%"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
