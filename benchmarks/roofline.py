"""Roofline table from the dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json; emits per (arch x shape x mesh): the three
roofline terms (compute / memory / collective, seconds), the dominant term,
MODEL_FLOPS/HLO_FLOPS, and a one-line "what would move the dominant term".
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")

_ADVICE = {
    "compute_s": "raise MXU utilization: larger per-device tiles (less TP), "
                 "Pallas-fused attention/SSD, bf16 throughout",
    "memory_s": "cut HBM traffic: fuse attention (no S^2 materialization), "
                "selective remat policy, bf16 master-free optimizer",
    "collective_s": "reshape layout: lower TP degree / batch-shard the model "
                    "axis, overlap grad all-reduce with bwd, compress grads",
}


def load_records(pattern: str = "*.json") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART, pattern))):
        try:
            recs = json.load(open(path))
        except json.JSONDecodeError:
            continue
        out.extend(recs if isinstance(recs, list) else [recs])
    return out


def fmt_row(r: dict) -> Optional[dict]:
    if r.get("skipped"):
        return {"arch": r["arch"], "shape": r["shape"], "mesh": "-",
                "skipped": True, "reason": r.get("reason", "")}
    if not r.get("ok"):
        return {"arch": r.get("arch"), "shape": r.get("shape"),
                "mesh": r.get("mesh"), "failed": True}
    terms = r["roofline"]
    dom = r["bottleneck"]
    total = max(sum(terms.values()), 1e-12)
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"], "bottleneck": dom,
        "roofline_fraction": terms["compute_s"] / max(terms.values()),
        "useful_flops_ratio": r.get("useful_flops_ratio", 0.0),
        "advice": _ADVICE[dom],
    }


def markdown_table(rows: List[dict]) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "bottleneck | MODEL/HLO flops | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r is None:
            continue
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"skipped: {r['reason'][:40]} | — | — |")
            continue
        if r.get("failed"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| FAILED | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['bottleneck'].replace('_s','')} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def run(mesh: Optional[str] = "16x16") -> List[dict]:
    """Default: single-pod only (the §Roofline table per spec); pass
    mesh=None for everything (the §Dry-run pass/fail listing)."""
    rows = [fmt_row(r) for r in load_records()]
    rows = [r for r in rows if r is not None]
    if mesh:
        rows = [r for r in rows
                if r.get("skipped") or r.get("mesh") == mesh]
    return rows


def dryrun_status() -> List[dict]:
    """Pass/fail per (arch, shape, mesh) — proves both meshes compile."""
    out = []
    for r in load_records():
        out.append({
            "arch": r.get("arch"), "shape": r.get("shape"),
            "mesh": r.get("mesh", "-"),
            "status": ("skipped" if r.get("skipped")
                       else "ok" if r.get("ok") else "FAILED"),
            "compile_s": r.get("compile_s"),
            "temp_gb": (r.get("memory", {}).get("temp_bytes", 0) or 0) / 1e9,
        })
    return out


def main():
    rows = run()
    print(markdown_table(rows))
    done = [r for r in rows if not r.get("skipped") and not r.get("failed")]
    if done:
        worst = min(done, key=lambda r: r["roofline_fraction"])
        coll = max(done, key=lambda r: r["collective_s"])
        print(f"\nworst roofline fraction: {worst['arch']} {worst['shape']} "
              f"{worst['mesh']} ({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound: {coll['arch']} {coll['shape']} "
              f"{coll['mesh']} ({coll['collective_s']:.3f}s)")


if __name__ == "__main__":
    main()
