"""Shared fleet-simulation workload for the planner benchmarks.

`scheduler_gains.py` and `cross_provider.py` both validate a planner's
best (region, launch-hour) cell with the same ensemble recipe — one
ResNet-32 x 4-worker job, simulated `ENSEMBLE_N` times via
`FleetSim.run_many` (pre-drawn batched lifetimes). Keeping the recipe
here means the two benchmarks can never silently diverge on the
workload they report.
"""
from __future__ import annotations

from repro.core.perf_model.speed_model import TABLE1_MODELS
from repro.core.transient.fleet import FleetSim, SimStats, SimWorker
from repro.models import cnn
from repro.providers import get_provider

# ResNet-32 at 4 workers, sized so the ~4-8 h wall-clock actually exposes
# each market's revocation behavior (same workload for every provider).
N_W = 256_000
I_C = 4_000
T_C = 3.84
N_WORKERS = 4
ENSEMBLE_N = 16


def best_cell_ensemble(provider, gpu: str, region: str, sp: float,
                       launch_hour: float, n_workers: int = N_WORKERS,
                       n: int = ENSEMBLE_N) -> SimStats:
    """Simulated distribution of the shared workload in one launch cell."""
    prov = get_provider(provider)
    workers = [SimWorker(i, gpu, region, sp) for i in range(n_workers)]
    sim = FleetSim(workers, model_gflops=TABLE1_MODELS["resnet_32"],
                   model_bytes=4.0 * cnn.param_count(cnn.RESNET_32),
                   step_speed_of=lambda g: sp,
                   checkpoint_interval_steps=I_C, checkpoint_time_s=T_C,
                   seed=0, price_of={gpu: prov.price(gpu)}, provider=prov)
    return sim.run_many(N_W, n, max_hours=100.0,
                        start_hour=launch_hour).stats
