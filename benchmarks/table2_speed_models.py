"""Table II — eight training-speed regression models (GPU-agnostic
univariate/multivariate OLS; per-GPU OLS and SVR poly/RBF) with k-fold and
test MAE, on the 20-CNN dataset (4 paper models + 16 custom variants).
"""
from __future__ import annotations

from repro.core.perf_model.speed_model import synth_dataset, table2_models
from repro.models import cnn


def dataset(seed: int = 0):
    models = {name: cnn.flops_per_image(spec) / 1e9
              for name, spec in cnn.ZOO.items()}
    return synth_dataset(models, samples_per=5, seed=seed)


def run():
    rows = dataset()
    reports = table2_models(rows)
    out = []
    for rep in reports:
        out.append({
            "name": f"table2/{rep.name}",
            "value": round(rep.test_mae, 4),
            "derived": (f"kfold={rep.kfold_mae:.4f}±{rep.kfold_mae_std:.4f} "
                        f"test_mape={rep.test_mape:.2f}% "
                        f"feat={rep.input_feature}"),
        })
    # the paper's headline: per-GPU SVR-RBF beats GPU-agnostic models
    best_specific = min(r.test_mae for r in reports
                        if r.name.startswith("svr_rbf"))
    agnostic = [r.test_mae for r in reports if "agnostic" in r.name]
    out.append({"name": "table2/specific_beats_agnostic",
                "value": int(best_specific < min(agnostic)),
                "derived": f"svr_rbf={best_specific:.4f} "
                           f"vs agnostic_min={min(agnostic):.4f}"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
