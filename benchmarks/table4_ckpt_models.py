"""Table IV — four checkpoint-time prediction models (univariate S_c,
multivariate (S_d,S_m), PCA-2, SVR-RBF) fitted on the measured Fig-5 data.
"""
from __future__ import annotations

from benchmarks.fig5_checkpoint import measure
from repro.core.perf_model.checkpoint_model import table4_models


def run():
    rows = measure(repeats=3)
    reports = table4_models(rows)
    out = []
    for rep in reports:
        out.append({"name": f"table4/{rep.name}",
                    "value": round(rep.test_mae, 4),
                    "derived": (f"kfold={rep.kfold_mae:.4f}"
                                f"±{rep.kfold_mae_std:.4f} "
                                f"mape={rep.test_mape:.2f}% "
                                f"feat={rep.input_feature}")})
    svr = next(r for r in reports if r.name == "svr_rbf")
    others = [r.kfold_mae for r in reports if r.name != "svr_rbf"]
    out.append({"name": "table4/svr_best_kfold",
                "value": int(svr.kfold_mae <= min(others) + 1e-9),
                "derived": f"svr={svr.kfold_mae:.4f} "
                           f"others_min={min(others):.4f}"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
