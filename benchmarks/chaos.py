"""Chaos scenario scorecard as benchmark rows: per-scenario recovery cost
(extra wall-clock / $ / revocations vs an unfaulted baseline on the same
draws) plus the live detection/mitigation quality numbers (docs/chaos.md).
"""
from __future__ import annotations

from repro.api.session import Session
from repro.chaos import get_scenario, list_scenarios, run_scenario

SAMPLES = 8
SEED = 0


def run():
    session = Session.from_arch("qwen3-1.7b", smoke=True)
    out = []
    for name in list_scenarios():
        card = run_scenario(get_scenario(name), session=session,
                            samples=SAMPLES, seed=SEED, smoke=True)
        if card["sim"] is None:
            # serving scenario: scored by benchmarks/serving.py
            continue
        imp = card["sim"]["impact"]
        par = card["sim"]["parity"]
        derived = (f"+${imp['extra_cost']:.2f} "
                   f"+{imp['extra_revocations']:.2f} revocations "
                   f"parity_err={par['time_max_rel_err']:.1e} "
                   f"smoke={'pass' if card['smoke']['passed'] else 'FAIL'}")
        live = card["live"]
        if live is not None:
            derived += (f" live[latency={live['detection_latency_steps']} "
                        f"missed={live['missed_detections']} "
                        f"false={live['false_alarms']} "
                        f"wrong={live['wrong_actions']} "
                        f"compression={live['final_compression']}]")
        out.append({"name": f"chaos/{name}",
                    "value": round(imp["extra_time_s"], 1),
                    "derived": derived + " (extra seconds vs baseline)"})
    return out
