"""Fig 12 / §VI-B — detect the PS bottleneck (predicted-vs-measured deviation
over the 6.7% threshold) and mitigate: add a second parameter server (the
paper reports up to 70.6% speed improvement) or compress the update
payload (docs/DESIGN.md §6) — the int8 rows show the compression lever
helps network-bound models and leaves RPC-bound ones (ResNet-32's 97
tensors) flat.
"""
from __future__ import annotations

from repro.core.controller import Action, Controller
from repro.core.perf_model.cluster_model import PSBottleneckModel, WorkerSpec, cluster_speed
from repro.core.perf_model.speed_model import TABLE1_MODELS, calibrate_generators
from repro.core.profiler import PerformanceProfiler
from repro.models import cnn


def run():
    import jax
    gens = calibrate_generators()
    out = []
    for model in ("resnet_15", "resnet_32"):
        c_m = TABLE1_MODELS[model]
        spec = cnn.RESNET_15 if model == "resnet_15" else cnn.RESNET_32
        mb = 4.0 * cnn.param_count(spec)
        nt = len(jax.tree.leaves(jax.eval_shape(
            lambda s=spec: cnn.init_params(jax.random.PRNGKey(0), s))))
        solo = 1.0 / gens["p100"].step_time(c_m)
        for n in (4, 6, 8):
            workers = [WorkerSpec("p100", solo)] * n
            ps1 = PSBottleneckModel(mb, 1, n_tensors=nt)
            measured = cluster_speed(workers, ps1)          # what profiler sees
            predicted = sum(w.speed for w in workers)       # sp = Σ sp_i
            # feed the profiler a synthetic measurement trace
            prof = PerformanceProfiler(window=2, warmup_steps=0,
                                       warmup_seconds=0.0)
            t = 0.0
            for s in range(8):
                prof.record(s, t=t)
                t += 1.0 / measured
            ctrl = Controller()
            det = ctrl.check(prof, predicted, ps1, workers)
            improved = cluster_speed(workers, ctrl.mitigate_ps(ps1))
            gain = (improved - measured) / measured * 100
            out.append({
                "name": f"fig12/{model}/p100x{n}",
                "value": round(gain, 1),
                "derived": (f"detected={det.bottleneck} action={det.action.value} "
                            f"speed {measured:.2f}->{improved:.2f} steps/s "
                            f"(gain %)"),
            })
            # the other §VI-B lever: int8 payload, no extra server
            ps8 = ctrl.mitigate_compression(ps1, "int8")
            comp = cluster_speed(workers, ps8)
            out.append({
                "name": f"fig12/{model}/p100x{n}/int8",
                "value": round((comp - measured) / measured * 100, 1),
                "derived": (f"ENABLE_COMPRESSION: capacity "
                            f"{ps1.capacity_steps_per_s():.2f}->"
                            f"{ps8.capacity_steps_per_s():.2f}, speed "
                            f"{measured:.2f}->{comp:.2f} steps/s (gain %)"),
            })
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
