"""The paper's motivation quantified: monetary cost + wall-clock of training
on transient vs on-demand clusters (fleet simulation with GCP-2019-era
prices), including revocation/replacement overheads and checkpointing.
"""
from __future__ import annotations

import numpy as np

from repro.core.perf_model.features import GPU_SPECS
from repro.core.perf_model.speed_model import TABLE1_MODELS, calibrate_generators
from repro.core.transient.fleet import FleetSim, SimWorker
from repro.models import cnn

# 8x the paper's ResNet-32 run so the wall-clock (~8h on 4xK80) actually
# exposes revocations; checkpoint interval unchanged.
N_W = 512_000
I_C = 4_000
T_C = 3.84


def _run(gpu: str, n: int, transient: bool, seeds=(0, 1, 2)):
    gens = calibrate_generators()
    c_m = TABLE1_MODELS["resnet_32"]
    sp = 1.0 / gens[gpu].step_time(c_m)
    spec = GPU_SPECS[gpu]
    price = spec.transient_price if transient else spec.hourly_price
    times, costs, revs = [], [], []
    for s in seeds:
        workers = [SimWorker(i, gpu, "us-central1", sp) for i in range(n)]
        sim = FleetSim(workers, model_gflops=c_m,
                       model_bytes=4.0 * cnn.param_count(cnn.RESNET_32),
                       step_speed_of=lambda g: sp,
                       checkpoint_interval_steps=I_C, checkpoint_time_s=T_C,
                       seed=s, price_of={gpu: price})
        if not transient:
            sim.rev.rng = np.random.default_rng(10_000 + s)
            # on-demand: suppress revocations by monkey-setting lifetimes inf
            sim.rev.lifetime = lambda *a, **k: float("inf")
        res = sim.run(N_W)
        times.append(res.total_time_s)
        costs.append(res.monetary_cost)
        revs.append(res.revocations)
    return float(np.mean(times)), float(np.mean(costs)), float(np.mean(revs))


def run():
    out = []
    for gpu, n in (("k80", 4), ("v100", 4)):
        t_tr, c_tr, r_tr = _run(gpu, n, transient=True)
        t_od, c_od, _ = _run(gpu, n, transient=False)
        save = (1 - c_tr / c_od) * 100
        slow = (t_tr / t_od - 1) * 100
        out.append({"name": f"cost/{gpu}x{n}",
                    "value": round(save, 1),
                    "derived": (f"transient ${c_tr:.2f}/{t_tr/3600:.2f}h "
                                f"({r_tr:.1f} revocations) vs on-demand "
                                f"${c_od:.2f}/{t_od/3600:.2f}h; "
                                f"{slow:+.1f}% slower (cost savings %)")})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
