"""§VI-A / Eq (4)(5) — end-to-end training-time prediction vs simulation.

Predict T for ResNet-32, N_w = 64K steps, I_c = 4K, on transient clusters
(homogeneous and heterogeneous), then run the discrete-event fleet simulator
with the same inputs and report the prediction error (paper: 0.8%).
"""
from __future__ import annotations

import numpy as np

from repro.core.perf_model.checkpoint_model import CheckpointTimePredictor
from repro.core.perf_model.cluster_model import (Eq4Inputs, WorkerSpec,
                                                 cluster_speed,
                                                 expected_revocations,
                                                 predict_total_time)
from repro.core.perf_model.speed_model import TABLE1_MODELS, calibrate_generators
from repro.core.transient.fleet import FleetSim, SimWorker
from repro.core.transient.replacement import ReplacementModel
from repro.core.transient.revocation import REGION_GPU_PARAMS
from repro.core.transient.startup import StartupModel
from repro.models import cnn

N_W = 64_000
I_C = 4_000
T_C = 3.84            # paper's measured ResNet-32 checkpoint seconds
REGION = "us-central1"


def scenario(counts, seed=0):
    gens = calibrate_generators()
    c_m = TABLE1_MODELS["resnet_32"]
    mb = 4.0 * cnn.param_count(cnn.RESNET_32)
    workers, specs = [], []
    wid = 0
    for gpu, n in counts.items():
        sp = 1.0 / gens[gpu].step_time(c_m)
        for _ in range(n):
            workers.append(SimWorker(wid, gpu, REGION, sp))
            specs.append(WorkerSpec(gpu, sp))
            wid += 1
    sp_cluster = cluster_speed(specs)  # PS below saturation for these sizes
    # Eq 4/5 inputs
    run_hours_guess = N_W / sp_cluster / 3600.0
    probs = [REGION_GPU_PARAMS[(REGION, w.gpu)].prob_revoked_within(
        min(run_hours_guess, 24.0)) for w in workers]
    startup = StartupModel(seed)
    repl = ReplacementModel(seed)
    t_p = float(np.mean([startup.mean_total(w.gpu) for w in workers]))
    t_s = repl.cold_start_s(c_m)
    pred = predict_total_time(sp_cluster, Eq4Inputs(
        N_W, I_C, T_C, t_p, t_s, probs))
    # simulate
    sims = []
    for s in range(4):
        sim = FleetSim(
            [SimWorker(w.wid, w.gpu, w.region, w.speed) for w in workers],
            model_gflops=c_m, model_bytes=mb,
            step_speed_of=lambda g: 1.0 / gens[g].step_time(c_m),
            checkpoint_interval_steps=I_C, checkpoint_time_s=T_C,
            seed=seed + s)
        sims.append(sim.run(N_W).total_time_s)
    sim_mean = float(np.mean(sims))
    err = abs(pred - sim_mean) / sim_mean * 100
    return pred, sim_mean, err, expected_revocations(probs)


def run():
    out = []
    for name, counts in [("k80x4", {"k80": 4}),
                         ("hetero_2k80_1p100_1v100",
                          {"k80": 2, "p100": 1, "v100": 1})]:
        pred, sim, err, n_r = scenario(counts)
        out.append({"name": f"eq4/{name}",
                    "value": round(err, 2),
                    "derived": f"pred={pred:.0f}s sim={sim:.0f}s "
                               f"E[revocations]={n_r:.2f} (err %)"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
