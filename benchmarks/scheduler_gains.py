"""Beyond-paper (§V-C future work, built): revocation-aware launch planning —
how much expected time/cost does choosing the right (region, launch hour)
save vs the worst naive choice? The best cell is then validated with a
`FleetSim.run_many` ensemble (pre-drawn batched lifetimes): the planner's
Eq (4) expectation should sit inside the simulated distribution.
"""
from __future__ import annotations

from benchmarks.fleet_common import I_C, N_W, T_C, best_cell_ensemble
from repro.core.perf_model.speed_model import TABLE1_MODELS, calibrate_generators
from repro.core.scheduler import plan_launch


def run():
    gens = calibrate_generators()
    c_m = TABLE1_MODELS["resnet_32"]
    out = []
    for gpu, n in (("k80", 4), ("v100", 4)):
        sp = 1.0 / gens[gpu].step_time(c_m)
        best, plans = plan_launch(gpu, n, sp, n_w=N_W, i_c=I_C, t_c=T_C)
        worst = max(plans, key=lambda p: p.expected_cost)
        time_save = (worst.expected_time_s - best.expected_time_s) \
            / worst.expected_time_s * 100
        cost_save = (worst.expected_cost - best.expected_cost) \
            / worst.expected_cost * 100
        st = best_cell_ensemble("gcp", gpu, best.region, sp,
                                float(best.launch_hour), n_workers=n)
        out.append({
            "name": f"scheduler/{gpu}x{n}",
            "value": round(cost_save, 1),
            "derived": (f"best={best.region}@{best.launch_hour:02d}h "
                        f"E[rev]={best.expected_revocations:.2f}"
                        f"±{best.revocation_stderr:.2f} "
                        f"vs worst={worst.region}@{worst.launch_hour:02d}h "
                        f"E[rev]={worst.expected_revocations:.2f}; "
                        f"time saved {time_save:.1f}%; best-cell ensemble "
                        f"(n={st.n}) time p50={st.time_p50_s / 3600:.2f}h "
                        f"p90={st.time_p90_s / 3600:.2f}h (cost saved %)"),
        })
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
