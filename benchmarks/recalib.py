"""Online-recalibration benchmark (docs/calibration.md): the straggler
live scenario unarmed vs armed.

Unarmed, the controller keeps comparing measurement against the stale
static prediction for the whole fault window — every post-detection check
re-flags the same deviation. Armed, CUSUM confirms the drift, the
cluster-speed estimator refits from profiler history, and the very next
check lands back inside the 6.7 % threshold while the straggler is still
active; rows report the refit ledger and the post-refit deviation, plus
both runs' detection/mitigation quality (which recalibration must not
degrade: no false alarms, no wrong PS levers for a straggler).
"""
from __future__ import annotations

import dataclasses

from repro.api.session import Session
from repro.calibration import RecalibrationConfig
from repro.chaos import get_scenario
from repro.chaos.runner import _run_live

SEED = 0


def _live(armed: bool) -> dict:
    session = Session.from_arch("qwen3-1.7b", smoke=True)
    if armed:
        session.run = dataclasses.replace(
            session.run, recalibration=RecalibrationConfig())
    return _run_live(session, get_scenario("straggler"), seed=SEED)


def run():
    out = []
    unarmed = _live(armed=False)
    armed = _live(armed=True)
    for label, live in (("unarmed", unarmed), ("armed", armed)):
        out.append({
            "name": f"recalib/straggler_{label}/detections",
            "value": live["detections"],
            "derived": (f"latency={live['detection_latency_steps']} "
                        f"missed={live['missed_detections']} "
                        f"false={live['false_alarms']} "
                        f"wrong={live['wrong_actions']} "
                        f"actions={live['actions_applied']}")})
    assert "recalibration" not in unarmed, \
        "unarmed run must not carry a recalibration scorecard"
    recal = armed["recalibration"]
    out.append({"name": "recalib/straggler_armed/refits",
                "value": len(recal["refits"]),
                "derived": (f"drift_events={len(recal['drift_events'])} "
                            f"model_version={recal['model_version']} "
                            + " ".join(
                                f"v{r['model_version']}:"
                                f"{r['old_speed']:.1f}->{r['new_speed']:.1f}"
                                for r in recal["refits"]))})
    out.append({"name": "recalib/straggler_armed/post_refit_deviation",
                "value": (round(abs(recal["post_refit_deviation"]), 4)
                          if recal["post_refit_deviation"] is not None
                          else float("nan")),
                "derived": "abs deviation at the first check after the "
                           "last refit (controller threshold 0.067)"})
    return out
