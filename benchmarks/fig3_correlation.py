"""Fig 3 — step time vs normalized computation ratio C_norm and normalized
model complexity C_m: the correlations that justify the §III regression
features (GPUs collapse onto one trend line under C_norm; separate lines
under C_m -> per-GPU models are worth building).
"""
from __future__ import annotations

import numpy as np

from repro.core.perf_model.features import c_norm, minmax_apply, minmax_fit
from repro.core.perf_model.speed_model import synth_dataset
from repro.models import cnn


def run():
    models = {name: cnn.flops_per_image(spec) / 1e9
              for name, spec in cnn.ZOO.items()}
    rows = synth_dataset(models, samples_per=5, seed=0)
    c_m = np.array([r["c_m"] for r in rows])
    c_g = np.array([r["c_gpu"] for r in rows])
    t = np.array([r["step_time"] for r in rows])
    cn = minmax_apply(c_norm(c_m, c_g), *minmax_fit(c_norm(c_m, c_g)))

    out = []
    r_all = float(np.corrcoef(cn, t)[0, 1])
    out.append({"name": "fig3/corr_step_time_vs_Cnorm_all_gpus",
                "value": round(r_all, 4),
                "derived": "GPUs collapse onto one line (paper: strong +)"})
    for gpu in ("k80", "p100", "v100"):
        sel = np.array([r["gpu"] == gpu for r in rows])
        r_gpu = float(np.corrcoef(c_m[sel], t[sel])[0, 1])
        out.append({"name": f"fig3/corr_step_time_vs_Cm_{gpu}",
                    "value": round(r_gpu, 4),
                    "derived": "per-GPU trend line"})
    # the separation claim: same C_m, different GPUs -> different step time
    sep = float(np.mean(t[c_g == 4.11]) / np.mean(t[c_g == 14.13]))
    out.append({"name": "fig3/k80_over_v100_step_time_ratio",
                "value": round(sep, 2),
                "derived": "distinct lines under C_m (>1 expected)"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
