"""Fig 5 + §IV-A — REAL checkpoint measurements: save all twenty CNNs with
the repo checkpointer, record (S_d, S_i, S_m) and wall-clock time.

Local disk writes are near-instant for small CNNs, so (as the paper saves to
cloud storage in-region) a calibrated remote-storage path adds modeled
upload time at GCS-like bandwidth. Both components are reported.
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core.perf_model.checkpoint_model import CkptRow
from repro.models import cnn

REMOTE_BW = 120e6       # bytes/s sustained to in-region cloud storage
REMOTE_LATENCY = 0.35   # per-checkpoint commit latency, seconds


def measure(repeats: int = 3, remote: bool = True):
    rows = []
    for name, spec in cnn.ZOO.items():
        params = cnn.init_params(jax.random.PRNGKey(0), spec)
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, holder="bench")
            times = []
            sizes = None
            for i in range(repeats):
                t0 = time.monotonic()
                sizes = ck.save(i, params)
                t = time.monotonic() - t0
                if remote:
                    t += REMOTE_LATENCY + sizes.total / REMOTE_BW
                times.append(t)
            rows.append(CkptRow(name, sizes.s_d, sizes.s_m, sizes.s_i,
                                float(np.mean(times))))
    return rows


def run():
    rows = measure()
    out = []
    for r in rows:
        out.append({"name": f"fig5/{r.model}",
                    "value": round(r.t_c, 4),
                    "derived": f"s_c={r.s_c/1e6:.2f}MB s_d={r.s_d/1e6:.2f}MB"})
    # correlation between size and time (the paper's positive correlation)
    sc = np.array([r.s_c for r in rows])
    tc = np.array([r.t_c for r in rows])
    corr = float(np.corrcoef(sc, tc)[0, 1])
    out.append({"name": "fig5/size_time_correlation", "value": round(corr, 4),
                "derived": "pearson r"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
