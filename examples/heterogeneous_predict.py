"""§VI-A use case: predict heterogeneous-cluster training speed and total
training time (Eq 4/5), then validate against the discrete-event fleet
simulator — the paper reports 0.8% error for ResNet-32.

PYTHONPATH=src python examples/heterogeneous_predict.py
"""
from __future__ import annotations

import numpy as np

from repro.core.perf_model.cluster_model import (Eq4Inputs,
                                                 HeterogeneousPredictor,
                                                 WorkerSpec, cluster_speed,
                                                 predict_total_time)
from repro.core.perf_model.speed_model import (TABLE1_MODELS,
                                               WorkerSpeedPredictor,
                                               calibrate_generators,
                                               synth_dataset)
from repro.core.transient.fleet import FleetSim, SimWorker
from repro.core.transient.revocation import REGION_GPU_PARAMS
from repro.models import cnn


def main():
    # 1. fit per-GPU SVR-RBF speed predictors on the measurement dataset
    models = {name: cnn.flops_per_image(spec) / 1e9
              for name, spec in cnn.ZOO.items()}
    rows = synth_dataset(models, samples_per=5, seed=0)
    preds = {g: WorkerSpeedPredictor.fit(rows, g)
             for g in ("k80", "p100", "v100")}
    c_m = TABLE1_MODELS["resnet_32"]
    print("predicted solo speeds for ResNet-32 (steps/s):",
          {g: round(p.speed(c_m), 2) for g, p in preds.items()})

    # 2. compose: sp = sum sp_i for a 2xK80 + 1xP100 + 1xV100 cluster
    counts = {"k80": 2, "p100": 1, "v100": 1}
    import jax
    nt = len(jax.tree.leaves(jax.eval_shape(
        lambda: cnn.init_params(jax.random.PRNGKey(0), cnn.RESNET_32))))
    hp = HeterogeneousPredictor({g: p.speed(c_m) for g, p in preds.items()},
                                model_bytes=4.0 * cnn.param_count(cnn.RESNET_32),
                                n_ps=1, n_tensors=nt)
    sp = hp.predict(counts)
    print(f"predicted cluster speed: {sp:.2f} steps/s")

    # 3. Eq (4)/(5): total time for 64K steps, I_c=4K
    region = "us-central1"
    n_w, i_c, t_c = 64000, 4000, 3.84
    hours = n_w / sp / 3600
    probs = [REGION_GPU_PARAMS[(region, g)].prob_revoked_within(
        min(hours, 24.0)) for g, n in counts.items() for _ in range(n)]
    pred_t = predict_total_time(sp, Eq4Inputs(n_w, i_c, t_c, 75.0, 40.0, probs))
    print(f"Eq(4) predicted total time: {pred_t:.0f}s "
          f"(E[revocations]={sum(probs):.2f})")

    # 4. validate against the fleet simulator
    gens = calibrate_generators()
    workers = []
    wid = 0
    for g, n in counts.items():
        for _ in range(n):
            workers.append(SimWorker(wid, g, region,
                                     1.0 / gens[g].step_time(c_m)))
            wid += 1
    sims = [FleetSim(list(workers), model_gflops=c_m,
                     model_bytes=4.0 * cnn.param_count(cnn.RESNET_32),
                     step_speed_of=lambda g: 1.0 / gens[g].step_time(c_m),
                     checkpoint_interval_steps=i_c, checkpoint_time_s=t_c,
                     seed=s).run(n_w).total_time_s for s in range(4)]
    sim_t = float(np.mean(sims))
    print(f"simulated total time: {sim_t:.0f}s "
          f"-> prediction error {abs(pred_t-sim_t)/sim_t*100:.1f}% "
          f"(paper: 0.8%)")


if __name__ == "__main__":
    main()
