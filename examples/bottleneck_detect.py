"""§VI-B use case: online PS-bottleneck detection and mitigation.

Streams measured speeds (async-PS queue sim) into the profiler, lets the
controller compare against the composed prediction (6.7% threshold after a
30s warmup), and follows the controller's escalation (docs/DESIGN.md §6):
compress the update payload first (free — no new server), then provision
a second parameter server if the cluster is still saturated. ResNet-32 is
RPC-bound (97 tensors), so compression alone does not move it and the
controller escalates to the PS lever.

PYTHONPATH=src python examples/bottleneck_detect.py
"""
from __future__ import annotations

import jax

from repro.core.controller import Action, Controller
from repro.core.perf_model.cluster_model import PSBottleneckModel, WorkerSpec
from repro.core.perf_model.speed_model import TABLE1_MODELS, calibrate_generators
from repro.core.profiler import PerformanceProfiler
from repro.core.ps_async import ps_queue_sim
from repro.models import cnn


def main():
    gens = calibrate_generators()
    c_m = TABLE1_MODELS["resnet_32"]
    step_p100 = gens["p100"].step_time(c_m)
    mb = 4.0 * cnn.param_count(cnn.RESNET_32)
    nt = len(jax.tree.leaves(jax.eval_shape(
        lambda: cnn.init_params(jax.random.PRNGKey(0), cnn.RESNET_32))))

    for n_workers in (2, 6):
        print(f"\n=== {n_workers} x P100 training ResNet-32, 1 PS ===")
        res = ps_queue_sim([step_p100] * n_workers, mb, n_ps=1, steps=200,
                           n_tensors=nt)
        measured = res.cluster_speed
        predicted = n_workers / step_p100          # sp = sum sp_i
        prof = PerformanceProfiler(window=5, warmup_steps=0,
                                   warmup_seconds=0.0)
        t = 0.0
        for s in range(12):
            prof.record(s, t=t)
            t += 1.0 / measured
        ctrl = Controller(threshold=0.067)
        workers = [WorkerSpec("p100", 1.0 / step_p100)] * n_workers
        ps = PSBottleneckModel(mb, 1, n_tensors=nt)
        det = ctrl.check(prof, predicted, ps, workers)
        print(f"measured {measured:.2f} vs predicted {predicted:.2f} steps/s "
              f"(deviation {det.deviation*100:.1f}%)")
        if det.bottleneck:
            print(f"BOTTLENECK -> {det.action.value}: {det.note}")
            if det.action is Action.ENABLE_COMPRESSION:
                ps = ctrl.mitigate_compression(ps, "int8")
                res2 = ps_queue_sim([step_p100] * n_workers, mb, n_ps=1,
                                    steps=200, n_tensors=nt,
                                    grad_compression=ps.compression)
                gain = (res2.cluster_speed - measured) / measured * 100
                print(f"after int8 compression: {res2.cluster_speed:.2f} "
                      f"steps/s (+{gain:.1f}%)")
                det = ctrl.check(prof, predicted, ps, workers)
            if det.action is Action.ADD_PARAMETER_SERVER:
                ps = ctrl.mitigate_ps(ps)
                res3 = ps_queue_sim([step_p100] * n_workers, mb,
                                    n_ps=ps.n_ps, steps=200, n_tensors=nt,
                                    grad_compression=ps.compression)
                gain = (res3.cluster_speed - measured) / measured * 100
                print(f"after adding PS: {res3.cluster_speed:.2f} steps/s "
                      f"(+{gain:.1f}%; paper reports up to 70.6%)")
        else:
            print("no bottleneck: measurement matches the model")


if __name__ == "__main__":
    main()
