"""Quickstart: pick an architecture, train a reduced config for a few steps
on CPU, checkpoint, restore, and predict the run's wall-clock with Eq (4).

PYTHONPATH=src python examples/quickstart.py --arch qwen3-1.7b --steps 20
"""
from __future__ import annotations

import argparse
import tempfile

import jax

from repro.configs import ARCH_IDS, RunConfig, get_config
from repro.core.perf_model.cluster_model import Eq4Inputs, predict_total_time
from repro.core.trainer import TransientTrainer
from repro.data.pipeline import ShardedLoader, SyntheticTokenSource


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"arch={cfg.name} (reduced): {cfg.n_layers}L d={cfg.d_model} "
          f"params={sum(p.size for p in jax.tree.leaves(__import__('repro.models.api', fromlist=['init']).init(cfg)[0])):,}")

    with tempfile.TemporaryDirectory() as d:
        run = RunConfig(total_steps=args.steps, warmup_steps=2,
                        checkpoint_interval=max(5, args.steps // 2),
                        checkpoint_dir=d, lr=1e-3, zero1=False)
        src = SyntheticTokenSource(cfg.vocab_size, args.seq)
        trainer = TransientTrainer(cfg, run, ShardedLoader(src, args.batch))
        state, start = trainer.restore_or_init()
        state, rep = trainer.run_steps(state, args.steps)
        print(f"loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f} over "
              f"{rep.steps_run} steps at {rep.speed or 0:.2f} steps/s, "
              f"{rep.checkpoints} checkpoints")

        state2, restored_step = trainer.restore_or_init()
        print(f"restore: latest checkpoint at step {restored_step}")

        # predict a hypothetical longer run with Eq (4)
        sp = rep.speed or 1.0
        pred = predict_total_time(sp, Eq4Inputs(
            n_w=10 * args.steps, i_c=run.checkpoint_interval,
            t_c=trainer.ckpt.last_save_seconds or 0.1,
            t_p=60.0, t_s=15.0, revoke_probs=[0.1]))
        print(f"Eq(4) predicted wall-clock for {10*args.steps} steps: "
              f"{pred:.1f}s")


if __name__ == "__main__":
    main()
