"""Quickstart via the `repro.api.Session` facade: pick an architecture,
train a reduced config for a few steps on CPU, checkpoint, restore, and
predict the run's wall-clock with Eq (4) — the whole CM-DARE loop in ~30
lines.

PYTHONPATH=src python examples/quickstart.py --arch qwen3-1.7b --steps 20
"""
from __future__ import annotations

import tempfile

from repro.api import Session
from repro.launch import cli


def main():
    p = cli.make_parser("quickstart", __doc__.splitlines()[0])
    cli.add_arch_arg(p)
    cli.add_batch_args(p)
    p.add_argument("--steps", type=int, default=20)
    args = p.parse_args()

    session = Session.from_arch(
        args.arch, total_steps=args.steps, warmup_steps=2, lr=1e-3,
        zero1=False, checkpoint_interval=max(5, args.steps // 2))
    info = session.describe()
    print(f"arch={args.arch} (reduced): {info['n_layers']}L "
          f"d={info['d_model']} params={info['params']:,}")

    with tempfile.TemporaryDirectory() as d:
        rep = session.train(args.steps, global_batch=args.global_batch,
                            seq_len=args.seq, checkpoint_dir=d)
        print(f"loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f} over "
              f"{rep.steps_run} steps at {rep.speed or 0:.2f} steps/s, "
              f"{rep.checkpoints} checkpoints")

        # a fresh restore sees the latest committed checkpoint
        _, restored_step = session.trainer.restore_or_init()
        print(f"restore: latest checkpoint at step {restored_step}")

        # predict a hypothetical 10x longer run on transient V100s, Eq (4)
        pred = session.predict(n_workers=1, gpu="v100",
                               steps=10 * args.steps)
        print(f"Eq(4) predicted wall-clock for {10*args.steps} steps on "
              f"1x{pred.gpu}: {pred.total_time_seconds:.1f}s "
              f"(E[revocations]={pred.expected_revocations:.2f})")


if __name__ == "__main__":
    main()
