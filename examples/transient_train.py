"""End-to-end driver on the Session facade: train a ~100M-param LM for a few
hundred steps on a transient cluster with revocations sampled from the
calibrated fleet model, checkpoint-lease handover, restore after a simulated
chief loss, and Eq(4) prediction vs. actual wall-clock.

Default runs a CPU-sized slice of the workload (reduced width, short run) so
it finishes in minutes; pass --full-100m for the real ~100M configuration.

PYTHONPATH=src python examples/transient_train.py --steps 300
"""
from __future__ import annotations

import math
import tempfile
import time

from repro.api import Session
from repro.configs import ModelConfig, RunConfig
from repro.core.trainer import MembershipEvent
from repro.core.transient.revocation import RevocationSampler
from repro.launch import cli


def lm_100m(full: bool) -> ModelConfig:
    if full:
        # ~100M-param decoder LM (GPT-2-small-ish, SwiGLU, GQA)
        return ModelConfig(name="lm-100m", family="dense", n_layers=12,
                           d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
                           d_ff=2048, vocab_size=32768, tie_embeddings=True)
    return ModelConfig(name="lm-14m", family="dense", n_layers=6,
                       d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
                       d_ff=768, vocab_size=8192, tie_embeddings=True)


def main():
    p = cli.make_parser("transient_train", __doc__.splitlines()[0])
    p.add_argument("--steps", type=int, default=300)
    cli.add_batch_args(p, batch_default=16, seq_default=128)
    p.add_argument("--members", type=int, default=4)
    p.add_argument("--full-100m", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = lm_100m(args.full_100m)
    run = RunConfig(total_steps=args.steps, warmup_steps=20,
                    checkpoint_interval=max(20, args.steps // 6),
                    lr=3e-4, zero1=False, seed=args.seed)
    # a custom (non-registry) ModelConfig goes straight into Session
    session = Session(cfg, run)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    # sample a revocation schedule from the calibrated fleet model: member i
    # is a preemptible v5e slice in us-central1 (v100 stats as proxy)
    samp = RevocationSampler(args.seed)
    events = []
    for i in range(1, args.members):  # member 0 survives
        lt = samp.lifetime("us-central1", "v100")
        if math.isfinite(lt):
            at_step = int(lt / 24.0 * args.steps)
            if 0 < at_step < args.steps:
                events.append(MembershipEvent(step=at_step, kind="revoke",
                                              member_id=i))
                # replacement joins ~startup-time later (scaled)
                rejoin = min(args.steps - 1, at_step + max(2, args.steps // 20))
                events.append(MembershipEvent(step=rejoin, kind="join",
                                              member_id=100 + i))
    print(f"sampled {sum(1 for e in events if e.kind=='revoke')} revocations "
          f"from the fleet model: "
          f"{[(e.kind, e.step) for e in sorted(events, key=lambda e: e.step)]}")

    # observe the run through the Session's event bus
    session.bus.subscribe(
        "epoch", lambda kind, ev: print(f"  [bus] step {ev['step']}: "
                                        f"{ev['kind']} member "
                                        f"{ev['member_id']} -> "
                                        f"{ev['n_alive']} alive"))

    with tempfile.TemporaryDirectory() as d:
        t0 = time.monotonic()
        half = args.steps // 2
        rep1 = session.train(half, global_batch=args.global_batch,
                             seq_len=args.seq, members=args.members,
                             events=[e for e in events if e.step < half],
                             checkpoint_dir=d)
        print(f"[phase 1] loss {rep1.losses[0]:.3f} -> {rep1.losses[-1]:.3f}, "
              f"{rep1.epochs} membership epochs, "
              f"{rep1.checkpoints} checkpoints, "
              f"{rep1.speed or 0:.2f} steps/s")

        # simulate chief loss: a fresh session (new lease holder) restores
        # and continues — the lease handover means no recomputation
        # reuse the subscribed bus so the observer sees phase-2 events too
        session2 = Session(cfg, run, bus=session.bus)
        # free the lease as the revocation notification would
        from repro.checkpoint import Checkpointer, WriterLease
        WriterLease(d, "worker-0").notify_revoked()
        resumed_step = Checkpointer(d).latest_step() or 0
        rep2 = session2.train(args.steps - resumed_step,
                              global_batch=args.global_batch,
                              seq_len=args.seq, members=args.members,
                              events=[e for e in events
                                      if e.step >= resumed_step],
                              holder="worker-replacement",
                              checkpoint_dir=d)
        lost = half - resumed_step
        print(f"[chief revoked] restored at step {resumed_step} "
              f"(recompute window {lost} steps, bounded by I_c="
              f"{run.checkpoint_interval})")
        wall = time.monotonic() - t0
        print(f"[phase 2] loss -> {rep2.losses[-1]:.3f}, "
              f"total wall {wall:.1f}s")
        full_losses = rep1.losses + rep2.losses
        assert full_losses[-1] < full_losses[0], "training must make progress"
        print(f"final loss {full_losses[-1]:.3f} "
              f"(start {full_losses[0]:.3f}) — OK")


if __name__ == "__main__":
    main()
