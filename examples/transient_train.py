"""End-to-end driver: train a ~100M-param LM for a few hundred steps on a
transient cluster with revocations sampled from the calibrated fleet model,
checkpoint-lease handover, restore after a simulated chief loss, and Eq(4)
prediction vs. actual wall-clock.

Default runs a CPU-sized slice of the workload (reduced width, short run) so
it finishes in minutes; pass --full-100m for the real ~100M configuration.

PYTHONPATH=src python examples/transient_train.py --steps 300
"""
from __future__ import annotations

import argparse
import math
import tempfile
import time

import jax
import numpy as np

from repro.configs import ModelConfig, RunConfig
from repro.core.trainer import MembershipEvent, TransientTrainer
from repro.core.transient.revocation import RevocationSampler
from repro.data.pipeline import ShardedLoader, SyntheticTokenSource
from repro.dist.elastic import Member


def lm_100m(full: bool) -> ModelConfig:
    if full:
        # ~100M-param decoder LM (GPT-2-small-ish, SwiGLU, GQA)
        return ModelConfig(name="lm-100m", family="dense", n_layers=12,
                           d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
                           d_ff=2048, vocab_size=32768, tie_embeddings=True)
    return ModelConfig(name="lm-14m", family="dense", n_layers=6,
                       d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
                       d_ff=768, vocab_size=8192, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--members", type=int, default=4)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = lm_100m(args.full_100m)
    n_params = sum(p.size for p in jax.tree.leaves(
        __import__("repro.models.api", fromlist=["init"]).init(cfg)[0]))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    # sample a revocation schedule from the calibrated fleet model: member i
    # is a preemptible v5e slice in us-central1 (v100 stats as proxy)
    samp = RevocationSampler(args.seed)
    events = []
    run_hours = 0.5  # compress the 24h fleet timeline onto this short run
    for i in range(1, args.members):  # member 0 survives
        lt = samp.lifetime("us-central1", "v100")
        if math.isfinite(lt):
            at_step = int(lt / 24.0 * args.steps)
            if 0 < at_step < args.steps:
                events.append(MembershipEvent(step=at_step, kind="revoke",
                                              member_id=i))
                # replacement joins ~startup-time later (scaled)
                rejoin = min(args.steps - 1, at_step + max(2, args.steps // 20))
                events.append(MembershipEvent(step=rejoin, kind="join",
                                              member_id=100 + i))
    print(f"sampled {sum(1 for e in events if e.kind=='revoke')} revocations "
          f"from the fleet model: "
          f"{[(e.kind, e.step) for e in sorted(events, key=lambda e: e.step)]}")

    with tempfile.TemporaryDirectory() as d:
        run = RunConfig(total_steps=args.steps, warmup_steps=20,
                        checkpoint_interval=max(20, args.steps // 6),
                        checkpoint_dir=d, lr=3e-4, zero1=False)
        src = SyntheticTokenSource(cfg.vocab_size, args.seq, seed=args.seed)
        trainer = TransientTrainer(
            cfg, run, ShardedLoader(src, args.batch),
            members=[Member(i) for i in range(args.members)])
        state, _ = trainer.restore_or_init()
        t0 = time.monotonic()
        half = args.steps // 2
        state, rep1 = trainer.run_steps(state, half, events=[
            e for e in events if e.step < half])
        print(f"[phase 1] loss {rep1.losses[0]:.3f} -> {rep1.losses[-1]:.3f}, "
              f"{rep1.epochs} membership epochs, "
              f"{rep1.checkpoints} checkpoints, "
              f"{rep1.speed or 0:.2f} steps/s")

        # simulate chief loss: a fresh trainer (new holder) restores and
        # continues — the lease handover means no recomputation
        trainer2 = TransientTrainer(cfg, run, ShardedLoader(src, args.batch),
                                    holder="worker-replacement")
        trainer2.ckpt.lease.notify_revoked()
        state2, resumed = trainer2.restore_or_init()
        lost = int(state.step) - resumed
        print(f"[chief revoked] restored at step {resumed} "
              f"(recompute window {lost} steps, bounded by I_c="
              f"{run.checkpoint_interval})")
        state2, rep2 = trainer2.run_steps(
            state2, args.steps - resumed,
            events=[e for e in events if e.step >= resumed])
        wall = time.monotonic() - t0
        print(f"[phase 2] loss -> {rep2.losses[-1]:.3f}, total wall {wall:.1f}s")
        full_losses = rep1.losses + rep2.losses
        assert full_losses[-1] < full_losses[0], "training must make progress"
        print(f"final loss {full_losses[-1]:.3f} "
              f"(start {full_losses[0]:.3f}) — OK")


if __name__ == "__main__":
    main()
